"""Figure 6 (extension): untar/extract-style small-file write workload —
the client-side write-behind pipeline vs synchronous writes.

The measured unit is the archive-extraction pattern that dominates the
paper's headline scenario: create + write (in tar-style blocksize chunks)
+ close N small files across a directory tree, with a pool of concurrent
workers, finishing with drain() so buffered data has actually landed (the
clock includes the flush):

  buffetfs-wb        write() buffers locally (0 critical RPCs); per-host
                     flusher threads coalesce extents and flush BATCHed
                     WRITE sub-messages off the critical path =>
                     1 critical RPC per file (the CREATE)
  buffetfs-wb-fsync  same pipeline, but fsync(fd) before every close —
                     the durability barrier drains the handle and adds one
                     critical FSYNC per file (the cost of caring)
  buffetfs-sync      every write() blocks on its own WRITE RPC =>
                     1 CREATE + chunks WRITEs critical per file
  lustre-normal      CREATE via the MDS + per-chunk WRITEs; everything
                     serializes on host 0 (DoM identical for writes)
  lustre-dom         same as lustre-normal on the write path (paper §5:
                     DoM does not help writes)

Target: write-behind issues >=3x fewer critical-path RPCs per written file
than the synchronous mode, and both beat the Lustre baselines on time.

    PYTHONPATH=src python -m benchmarks.fig6_write [--quick]
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Sequence

from repro.core import BLib, BuffetCluster, LustreNormalClient
from repro.core.perms import O_CREAT, O_WRONLY
from repro.core.transport import LatencyModel

from .common import fresh_cluster, make_client

# same ms-scale calibration as the other paper benchmarks (common.py)
FIG6_LATENCY = LatencyModel(rtt_us=1500.0, per_mib_us=2000.0, service_us=800.0)

FILE_COUNTS = (256, 1024)
SYSTEMS = ("buffetfs-wb", "buffetfs-wb-fsync", "buffetfs-sync",
           "lustre-normal", "lustre-dom")
FILE_SIZE = 4096
CHUNKS = 4        # tar extracts in blocksize chunks: several write()s per file
N_DIRS = 8
WORKERS = 4


def _mkdirs(cluster: BuffetCluster, system: str, prefix: str = "/untar"
            ) -> List[str]:
    """Pre-create the target directory tree through a zero-latency admin
    path (the archive's file *contents* are the workload; the dirs are not)."""
    lat = cluster.transport.latency
    cluster.transport.latency = LatencyModel(0, 0, 0)
    dirs = [f"{prefix}/d{d:03d}" for d in range(N_DIRS)]
    if system.startswith("buffetfs"):
        agent, _ = make_client("buffetfs", cluster)
        lib = BLib(agent)
        for d in dirs:
            lib.makedirs(d)
        agent.drain()
        agent.shutdown()
    else:
        c = LustreNormalClient(cluster)
        c.mkdir(prefix)
        for d in dirs:
            c.mkdir(d)
        c.drain()
        c.shutdown()
    cluster.transport.latency = lat
    return dirs


def _untar_worker(client, paths: List[str], payload: bytes,
                  fsync_every: bool) -> None:
    step = max(1, len(payload) // CHUNKS)
    chunks = [payload[i : i + step] for i in range(0, len(payload), step)]
    for p in paths:
        fd = client.open(p, O_WRONLY | O_CREAT)
        for ch in chunks:
            client.write(fd, ch)
        if fsync_every:
            client.fsync(fd)
        client.close(fd)
    errs = client.drain()  # the clock includes flushing buffered data
    assert not errs, f"{errs} async write/close failures"


def run(file_counts: Sequence[int] = FILE_COUNTS,
        latency: LatencyModel = FIG6_LATENCY,
        systems: Sequence[str] = SYSTEMS,
        workers: int = WORKERS) -> List[Dict]:
    rows: List[Dict] = []
    payload = b"u" * FILE_SIZE
    for n_files in file_counts:
        for system in systems:
            kind = {"buffetfs-wb": "buffetfs-wb",
                    "buffetfs-wb-fsync": "buffetfs-wb",
                    "buffetfs-sync": "buffetfs"}.get(system, system)
            with fresh_cluster(latency=latency) as cluster:
                dirs = _mkdirs(cluster, system)
                paths = [f"{dirs[i % N_DIRS]}/f{i:05d}"
                         for i in range(n_files)]
                clients = [make_client(kind, cluster)
                           for _ in range(workers)]
                shards = [paths[i::workers] for i in range(workers)]
                barrier = threading.Barrier(workers + 1)
                errors: List[Exception] = []

                def worker(wid: int) -> None:
                    client, _ = clients[wid]
                    barrier.wait()
                    try:
                        _untar_worker(client, shards[wid], payload,
                                      system == "buffetfs-wb-fsync")
                    except Exception as e:  # pragma: no cover
                        errors.append(e)

                threads = [threading.Thread(target=worker, args=(i,))
                           for i in range(workers)]
                for t in threads:
                    t.start()
                barrier.wait()
                t0 = time.perf_counter()
                for t in threads:
                    t.join()
                elapsed = time.perf_counter() - t0
                assert not errors, errors
                snaps = [c.stats.snapshot() for c, _ in clients]
                crit = sum(s["critical_path"] for s in snaps)
                rows.append({
                    "bench": "fig6_write", "system": system,
                    "n_files": n_files, "workers": workers,
                    "chunks_per_file": CHUNKS, "file_size": FILE_SIZE,
                    "seconds": round(elapsed, 3),
                    "critical_rpcs": crit,
                    "total_rpcs": sum(s["total"] for s in snaps),
                    "subops": sum(s["subops"] for s in snaps),
                    "crit_rpcs_per_file": round(crit / n_files, 4),
                })
                for c, _ in clients:
                    if hasattr(c, "shutdown"):
                        c.shutdown()
    return rows


def verdict(rows: List[Dict], n_files: int) -> List[str]:
    """Acceptance statement: write-behind issues >=3x fewer critical-path
    RPCs per written file than the synchronous mode and is faster, and both
    BuffetFS modes beat the Lustre baselines on wall-clock time."""
    by = {r["system"]: r for r in rows if r["n_files"] == n_files}
    wb, sync = by.get("buffetfs-wb"), by.get("buffetfs-sync")
    ln, ld = by.get("lustre-normal"), by.get("lustre-dom")
    lines = []
    if wb and sync:
        ratio = sync["crit_rpcs_per_file"] / max(1e-9,
                                                 wb["crit_rpcs_per_file"])
        lines.append(
            f"n={n_files}: write-behind {wb['crit_rpcs_per_file']} vs sync "
            f"{sync['crit_rpcs_per_file']} critical RPCs/file "
            f"({ratio:.1f}x fewer; {'PASS' if ratio >= 3 else 'FAIL'} >=3x), "
            f"{wb['seconds']}s vs {sync['seconds']}s "
            f"({'PASS' if wb['seconds'] < sync['seconds'] else 'FAIL'} faster)")
    if wb and sync and ln and ld:
        lmin = min(ln["seconds"], ld["seconds"])
        beats = wb["seconds"] < lmin and sync["seconds"] < lmin
        lines.append(
            f"n={n_files}: buffetfs wb {wb['seconds']}s / sync "
            f"{sync['seconds']}s vs lustre-normal {ln['seconds']}s / "
            f"lustre-dom {ld['seconds']}s "
            f"({'PASS' if beats else 'FAIL'} both beat both baselines)")
    return lines


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    counts = (128,) if args.quick else FILE_COUNTS
    rows = run(file_counts=counts)
    for r in rows:
        print(f"fig6,{r['system']},n={r['n_files']},w={r['workers']},"
              f"{r['seconds']}s,crit={r['critical_rpcs']}"
              f",crit/file={r['crit_rpcs_per_file']},subops={r['subops']}")
    for n in counts:
        for line in verdict(rows, n):
            print(line)


if __name__ == "__main__":
    main()
