"""Paper Figure 4: total execution time of concurrent access to many small
files (paper: N processes x 1000 random files from 100,000 4KB files).

Scaled for CI (default 8 workers x 100 files from a 2,000-file set; pass
--paper-scale for the full 1000x100k run).  The mechanism under test is
identical: every Lustre open() serializes on the single MDS while BuffetFS
clients hit independent BServers with zero metadata RPCs after warm-up —
the gap GROWS with concurrency, which is the paper's headline (up to 70%).
"""
from __future__ import annotations

import argparse
import random
import threading
import time
from typing import Dict, List

from .common import access_file, fresh_cluster, make_client, mkfiles

SYSTEMS = ("buffetfs", "lustre-normal", "lustre-dom")


def run_one(system: str, n_workers: int, files_per_worker: int,
            n_files: int, size: int = 4096, n_dirs: int = 8) -> Dict:
    with fresh_cluster() as cluster:
        paths = mkfiles(cluster, n_files=n_files, size=size, n_dirs=n_dirs,
                        system=system)
        clients = [make_client(system, cluster) for _ in range(n_workers)]
        barrier = threading.Barrier(n_workers + 1)
        errors: List[Exception] = []

        def worker(wid: int) -> None:
            client, _ = clients[wid]
            rng = random.Random(wid)
            picks = [rng.choice(paths) for _ in range(files_per_worker)]
            barrier.wait()
            try:
                for p in picks:
                    access_file(client, p)
            except Exception as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_workers)]
        for t in threads:
            t.start()
        barrier.wait()
        t0 = time.perf_counter()
        for t in threads:
            t.join()
        total_s = time.perf_counter() - t0
        crit = sum(c.stats.snapshot()["critical_path"] for c, _ in clients)
        for c, _ in clients:
            if hasattr(c, "shutdown"):
                c.shutdown()
        assert not errors, errors
        return {
            "bench": "fig4_concurrency", "system": system,
            "workers": n_workers, "files_per_worker": files_per_worker,
            "total_s": round(total_s, 3),
            "critical_rpcs": crit,
            "us_per_access": round(total_s * 1e6
                                   / (n_workers * files_per_worker), 1),
        }


def run(workers=(1, 2, 4, 8), files_per_worker: int = 100,
        n_files: int = 2000) -> List[Dict]:
    rows = []
    for nw in workers:
        for system in SYSTEMS:
            rows.append(run_one(system, nw, files_per_worker, n_files))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true",
                    help="1000 files/worker over a 100k-file set")
    args = ap.parse_args()
    if args.paper_scale:
        rows = run(workers=(1, 2, 4, 8, 16), files_per_worker=1000,
                   n_files=100_000)
    else:
        rows = run()
    for r in rows:
        print(f"fig4,{r['system']},workers={r['workers']},"
              f"{r['total_s']}s,{r['us_per_access']}us/access,"
              f"rpcs={r['critical_rpcs']}")


if __name__ == "__main__":
    main()
