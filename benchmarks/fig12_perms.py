"""Fig 12: rich serve-yourself permissions — ACL/group grants under leases.

Two deterministic multi-tenant scenarios, gated on RPC/counter
arithmetic (never wall-clock), pinning the paper's "serve yourself"
claim after the permission model grows past plain mode bits:

  * warm_grants — N tenants share one project tree at depth 4.  A third
    of the files are readable through per-user ACL entries, a third
    through a group entry resolved against the cluster group table, and
    a third carry no grant at all (mode 0o640, root-owned).  After one
    cold pass, every warm permission check — allowed AND denied — must
    cost ZERO critical-path RPCs and ZERO group-table fetches: the ACL
    rides in the cached dentry, the group table is cached client-side,
    and a denial is decided locally without ever touching a server.
    Exactly one group-table fetch per tenant is allowed, on the cold
    pass.  Replication is ON the whole time, so the gate also pins that
    ACL and group-table records ship through the commit log without
    touching the read path.
  * revoke — grants are withdrawn two ways (SETACL clearing the entry
    list, SETGROUPS dropping the membership) while every tenant holds
    warm dentries AND cached data blocks.  Because both verbs
    invalidate-before-ack (§3.4 two-phase for SETACL, a blocking
    group-watcher fan-out for SETGROUPS), the very next open() by every
    tenant must fail EACCES — `stale_allows` counts any read that still
    succeeds after the revoking verb returned, and must be zero.
"""

from __future__ import annotations

import argparse
import errno
import json
import tempfile
from typing import Dict, List

from repro.core import BAgent, BLib, BuffetCluster
from repro.core.perms import Credentials

# one TTL, long enough that no grant expires mid-scenario: every denial
# in the revoke scenario must come from the invalidation protocol, never
# from a lease quietly timing out
TTL_S = 30.0

TEAM_GID = 500
UID_BASE = 1001
DEPTH4 = "/proj/team/src/deep"


def _pattern(i: int, size: int) -> bytes:
    return bytes((i * 11 + j) % 251 for j in range(size))


def _sum_srv(cluster: BuffetCluster, attr: str) -> int:
    return sum(getattr(s, attr) for s in cluster.servers.values())


def _tenants(cluster: BuffetCluster, n_users: int) -> List[BLib]:
    return [
        BLib(
            BAgent(
                cluster,
                cred=Credentials(uid=UID_BASE + k, gid=100 + k),
                read_cache=True,
            )
        )
        for k in range(n_users)
    ]


def _read_all(
    lib: BLib, grants: Dict[str, bytes], denials: List[str], counts: Dict[str, int]
) -> None:
    """One full pass by one tenant: every granted file must read back
    intact, every ungranted file must deny with EACCES — both decided
    against cached state on a warm pass."""
    for p, want in grants.items():
        if lib.read_file(p) == want:
            counts["granted_ok"] += 1
    for p in denials:
        try:
            lib.read_file(p)
        except OSError as e:
            if e.errno == errno.EACCES:
                counts["denied"] += 1


def _warm_grants(n_users: int, n_files: int, warm_passes: int, size: int) -> Dict:
    with tempfile.TemporaryDirectory() as root:
        cluster = BuffetCluster(
            root_dir=root, n_servers=4, replication=True, lease_ttl_s=TTL_S
        )
        try:
            admin = BLib(BAgent(cluster))
            admin.makedirs(DEPTH4)
            blobs: Dict[str, bytes] = {}
            for i in range(n_files):
                p = f"{DEPTH4}/f{i:03d}"
                blobs[p] = _pattern(i, size)
                admin.write_file(p, blobs[p], perm=0o640)
            paths = sorted(blobs)
            by_user = [p for i, p in enumerate(paths) if i % 3 == 0]
            by_group = [p for i, p in enumerate(paths) if i % 3 == 1]
            ungranted = [p for i, p in enumerate(paths) if i % 3 == 2]

            uids = [UID_BASE + k for k in range(n_users)]
            for p in by_user:
                admin.setacl(p, [["u", u, 4, 0] for u in uids])
            for p in by_group:
                admin.setacl(p, [["g", TEAM_GID, 4, 0]])
            for u in uids:
                admin.setgroups(u, [TEAM_GID])

            tenants = _tenants(cluster, n_users)
            grants = {p: blobs[p] for p in by_user + by_group}
            cold = {"granted_ok": 0, "denied": 0}
            for lib in tenants:
                lib.warm_tree("/")
                _read_all(lib, grants, ungranted, cold)
            cold_crit = sum(
                t.agent.stats.snapshot()["critical_path"] for t in tenants
            )
            cold_fetches = sum(t.agent.perm_check_rpcs for t in tenants)

            for t in tenants:
                t.agent.stats.reset()
            warm = {"granted_ok": 0, "denied": 0}
            for _ in range(warm_passes):
                for lib in tenants:
                    _read_all(lib, grants, ungranted, warm)
            warm_crit = sum(
                t.agent.stats.snapshot()["critical_path"] for t in tenants
            )
            warm_fetches = (
                sum(t.agent.perm_check_rpcs for t in tenants) - cold_fetches
            )

            lag = 0
            for srv in cluster.servers.values():
                srv.repl_drain()
                lag += srv.repl_stats().get("repl_lag", 0)
            return {
                "bench": "fig12_perms",
                "mode": "warm_grants",
                "users": n_users,
                "n_files": n_files,
                "depth": 4,
                "warm_passes": warm_passes,
                "cold_crit_rpcs": cold_crit,
                "warm_crit_rpcs": warm_crit,
                "group_fetch_rpcs": cold_fetches,
                "group_fetch_expected": n_users,
                "warm_group_fetch_rpcs": warm_fetches,
                "granted_ok": cold["granted_ok"] + warm["granted_ok"],
                "granted_expected": n_users * len(grants) * (1 + warm_passes),
                "denied": cold["denied"] + warm["denied"],
                "denied_expected": n_users * len(ungranted) * (1 + warm_passes),
                "lease_breaks_forced": _sum_srv(cluster, "lease_breaks_forced"),
                "repl_lag_after": lag,
            }
        finally:
            cluster.shutdown()


def _revoke(n_users: int, size: int) -> Dict:
    with tempfile.TemporaryDirectory() as root:
        cluster = BuffetCluster(
            root_dir=root, n_servers=4, replication=True, lease_ttl_s=TTL_S
        )
        try:
            admin = BLib(BAgent(cluster))
            admin.makedirs("/rv")
            va, vb = _pattern(1, size), _pattern(2, size)
            admin.write_file("/rv/by_acl", va, perm=0o640)
            admin.write_file("/rv/by_group", vb, perm=0o640)

            uids = [UID_BASE + k for k in range(n_users)]
            admin.setacl("/rv/by_acl", [["u", u, 4, 0] for u in uids])
            admin.setacl("/rv/by_group", [["g", TEAM_GID, 4, 0]])
            for u in uids:
                admin.setgroups(u, [TEAM_GID])

            tenants = _tenants(cluster, n_users)
            allowed_before = 0
            for lib in tenants:
                lib.warm_tree("/")
                if lib.read_file("/rv/by_acl") == va:
                    allowed_before += 1
                if lib.read_file("/rv/by_group") == vb:
                    allowed_before += 1

            # every tenant now holds a warm dentry (with the granting
            # ACL) and cached data blocks for both files: the revokes
            # below must beat all of that state on the very next open
            stale_allows = 0
            admin.setacl("/rv/by_acl", None)
            denied_acl = 0
            for lib in tenants:
                try:
                    lib.read_file("/rv/by_acl")
                    stale_allows += 1
                except OSError as e:
                    if e.errno == errno.EACCES:
                        denied_acl += 1

            for u in uids:
                admin.setgroups(u, [])
            denied_group = 0
            for lib in tenants:
                try:
                    lib.read_file("/rv/by_group")
                    stale_allows += 1
                except OSError as e:
                    if e.errno == errno.EACCES:
                        denied_group += 1
            return {
                "bench": "fig12_perms",
                "mode": "revoke",
                "users": n_users,
                "allowed_before": allowed_before,
                "allowed_expected": 2 * n_users,
                "denied_after_acl_revoke": denied_acl,
                "acl_denies_expected": n_users,
                "denied_after_group_revoke": denied_group,
                "group_denies_expected": n_users,
                "stale_allows": stale_allows,
                "lease_breaks_forced": _sum_srv(cluster, "lease_breaks_forced"),
            }
        finally:
            cluster.shutdown()


def run(
    n_users: int = 6, n_files: int = 18, warm_passes: int = 3, size: int = 2048
) -> List[Dict]:
    return [
        _warm_grants(n_users, n_files, warm_passes, size),
        _revoke(n_users, size),
    ]


def check(rows: List[Dict]) -> List[str]:
    """Acceptance gates over `run()` rows; returns failure strings.

    Shared by the `--check` CLI (the CI fault-smoke lane) and
    benchmarks.run so the two gate sets can never drift.  Every gate is
    a counter comparison — never wall-clock."""
    failures: List[str] = []
    by_mode = {r.get("mode"): r for r in rows if r.get("bench") == "fig12_perms"}
    wg = by_mode.get("warm_grants")
    if wg:
        if wg["warm_crit_rpcs"] or wg["warm_group_fetch_rpcs"]:
            failures.append(
                f"fig12 warm_grants: {wg['warm_crit_rpcs']} critical RPCs, "
                f"{wg['warm_group_fetch_rpcs']} group fetches across warm "
                f"passes (every warm ACL/group check must be served from "
                f"client state)"
            )
        if wg["group_fetch_rpcs"] > wg["group_fetch_expected"]:
            failures.append(
                f"fig12 warm_grants: {wg['group_fetch_rpcs']} group-table "
                f"fetches (> {wg['group_fetch_expected']}: more than one "
                f"cold fetch per tenant)"
            )
        if wg["granted_ok"] != wg["granted_expected"]:
            failures.append(
                f"fig12 warm_grants: {wg['granted_ok']}/"
                f"{wg['granted_expected']} granted reads succeeded "
                f"(an ACL or group grant stopped admitting)"
            )
        if wg["denied"] != wg["denied_expected"]:
            failures.append(
                f"fig12 warm_grants: {wg['denied']}/{wg['denied_expected']} "
                f"ungranted opens denied (mode-bit fallback leaked access)"
            )
        if wg["repl_lag_after"] != 0:
            failures.append(
                f"fig12 warm_grants: replication lag {wg['repl_lag_after']} "
                f"after drain (ACL/group records stalled the shipper)"
            )
    rv = by_mode.get("revoke")
    if rv:
        if rv["stale_allows"]:
            failures.append(
                f"fig12 revoke: {rv['stale_allows']} reads succeeded after "
                f"their grant was revoked (invalidate-before-ack broke)"
            )
        if rv["allowed_before"] != rv["allowed_expected"]:
            failures.append(
                f"fig12 revoke: only {rv['allowed_before']}/"
                f"{rv['allowed_expected']} pre-revoke reads succeeded"
            )
        if rv["denied_after_acl_revoke"] != rv["acl_denies_expected"]:
            failures.append(
                f"fig12 revoke: {rv['denied_after_acl_revoke']}/"
                f"{rv['acl_denies_expected']} tenants denied after SETACL"
            )
        if rv["denied_after_group_revoke"] != rv["group_denies_expected"]:
            failures.append(
                f"fig12 revoke: {rv['denied_after_group_revoke']}/"
                f"{rv['group_denies_expected']} tenants denied after SETGROUPS"
            )
    for mode, r in by_mode.items():
        if r["lease_breaks_forced"]:
            failures.append(
                f"fig12 {mode}: {r['lease_breaks_forced']} forced lease "
                f"breaks (TTL discipline must keep this at zero)"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="smaller sweep")
    ap.add_argument("--out", help="write scenario rows to this JSON file")
    ap.add_argument(
        "--check",
        action="store_true",
        help="exit nonzero unless every acceptance gate holds",
    )
    args = ap.parse_args(argv)
    rows = run(
        n_users=4 if args.quick else 6,
        n_files=9 if args.quick else 18,
        warm_passes=2 if args.quick else 3,
    )
    print(json.dumps(rows, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
            f.write("\n")
    if args.check:
        failures = check(rows)
        for msg in failures:
            print(f"GATE FAIL: {msg}")
        if failures:
            return 1
        print("fig12 gates: ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
