"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms per cell, in seconds per step (per-device quantities from the
SPMD-partitioned HLO, so no division by chip count is needed):

  compute    = HLO_FLOPs_per_device   / 197e12   (bf16 peak, TPU v5e)
  memory     = HLO_bytes_per_device   / 819e9    (HBM bandwidth)
  collective = coll_bytes_per_device  / 50e9     (per-link ICI; DCN for pod)

HLO_FLOPs/bytes come from repro.analysis.hlo (while-loop trip counts
applied); MODEL_FLOPS from repro.analysis.model_math (6*N_active*D).  The
useful-compute ratio MODEL_FLOPS/HLO_FLOPS flags remat/redundancy waste
(remat target ~0.75 for train: one extra forward).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

HERE = os.path.dirname(__file__)
DRYRUN_JSON = os.path.join(HERE, "results", "dryrun.json")
HLO_DIR = os.path.join(HERE, "results", "hlo")
OUT_JSON = os.path.join(HERE, "results", "roofline.json")


def _cells() -> Dict[str, Dict]:
    with open(DRYRUN_JSON) as f:
        return json.load(f)


def analyze_cell(key: str, rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    import sys
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    from repro.analysis.hlo import analyze_file
    from repro.analysis.model_math import model_flops
    from repro.configs import get_config
    from repro.configs.base import ALL_SHAPES

    arch, shape_name, mesh = key.split("|")
    hlo_path = os.path.join(HLO_DIR, f"{arch}_{shape_name}_{mesh}.hlo.txt")
    if not os.path.exists(hlo_path):
        return None
    h = analyze_file(hlo_path)
    cfg = get_config(arch)
    shape = next(s for s in ALL_SHAPES if s.name == shape_name)
    mf = model_flops(cfg, shape)
    n_dev = rec["devices"]

    compute_s = h["flops"] / PEAK_FLOPS
    memory_s = h["hbm_bytes"] / HBM_BW
    coll_s = h["collective_bytes"] / ICI_BW
    dom = max((compute_s, "compute"), (memory_s, "memory"),
              (coll_s, "collective"))[1]
    useful = (mf["total"] / n_dev) / max(h["flops"], 1.0)
    bound_s = max(compute_s, memory_s, coll_s)
    # roofline fraction: useful-model-compute time over the bounding term
    model_compute_s = (mf["total"] / n_dev) / PEAK_FLOPS
    frac = model_compute_s / max(bound_s, 1e-30)

    # --- Pallas-kernel deployment estimate -------------------------------
    # On TPU the flash-attention / SSD kernels keep scores (or the SSD
    # decay quadratic) in VMEM: the attention-interior HBM traffic becomes
    # just q/k/v/out in bf16.  The XLA path we lower on CPU materializes
    # them.  Model the deployed memory term by replacing the attention's
    # measured share with the analytic kernel traffic.
    la = 0
    try:
        from repro.analysis.model_math import n_attn_layers
        la = n_attn_layers(cfg)
    except Exception:
        pass
    kern_mem_s = None
    if shape.kind in ("train", "prefill") and la:
        dh = (cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim) if cfg.mla \
            else cfg.head_dim
        toks = shape.seq_len * shape.global_batch
        passes = 3.0 if shape.kind == "train" else 1.0
        qkvo = 4.0 * toks * cfg.n_heads * dh * 2 * passes / n_dev
        # measured attention-interior traffic ~= everything above the
        # parameter/optimizer floor that scales with S^2; approximate by
        # capping the memory term at (non-attention bytes + kernel bytes),
        # where non-attention bytes ~= hbm_bytes - score-traffic estimate
        score_traffic = (passes * la * shape.global_batch * cfg.n_heads
                         * shape.seq_len * shape.seq_len * 4 * 2 / n_dev)
        non_attn = max(h["hbm_bytes"] - score_traffic, 0.0)
        kern_mem_s = (non_attn + la * qkvo) / HBM_BW
    return {
        "key": key, "arch": arch, "shape": shape_name, "mesh": mesh,
        "devices": n_dev,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dom,
        "hlo_flops_per_dev": h["flops"],
        "hlo_bytes_per_dev": h["hbm_bytes"],
        "coll_bytes_per_dev": h["collective_bytes"],
        "coll_breakdown": h["collectives"],
        "model_flops_total": mf["total"],
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "kernelized_memory_s": kern_mem_s,
        "hbm_per_dev_gib": (rec["memory"]["argument_bytes"]
                            + rec["memory"]["temp_bytes"]) / 2**30,
    }


def run(mesh: str = "16x16") -> List[Dict]:
    """Single-pod roofline table (the brief's §Roofline scope)."""
    rows = []
    for key, rec in sorted(_cells().items()):
        if not key.endswith(f"|{mesh}"):
            continue
        row = analyze_cell(key, rec)
        if row:
            rows.append(row)
    os.makedirs(os.path.dirname(OUT_JSON), exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def fmt_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':28s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dom':>6s} {'useful':>7s} {'roofline%':>9s} "
           f"{'HBM GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:28s} {r['shape']:12s} "
            f"{r['compute_s']*1e3:8.1f}ms {r['memory_s']*1e3:8.1f}ms "
            f"{r['collective_s']*1e3:8.1f}ms {r['dominant'][:6]:>6s} "
            f"{r['useful_flops_ratio']:7.2f} "
            f"{r['roofline_fraction']*100:8.1f}% "
            f"{r['hbm_per_dev_gib']:8.1f}")
    return "\n".join(lines)


def main() -> None:
    rows = run()
    print(fmt_table(rows))
    print(f"\n{len(rows)} cells analyzed -> {OUT_JSON}")


if __name__ == "__main__":
    main()
