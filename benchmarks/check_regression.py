"""Benchmark-regression gate: compare a fresh ``paper_bench.json`` against
the committed RPC-count baseline.

Only DETERMINISTIC metrics are gated — critical-path RPC counts, never
wall-clock — so a loaded CI runner cannot flake the gate.  A run regresses
when any gated metric exceeds its committed ceiling, or when a baselined
metric disappears from the results (a benchmark silently dropped is a
regression too).  Improvements are reported but never fail.

The committed baseline is generated from (and applies to) ``--quick`` runs,
which is what the CI bench-smoke job executes:

    PYTHONPATH=src python -m benchmarks.run --quick
    PYTHONPATH=src python -m benchmarks.check_regression \
        --actual benchmarks/results/paper_bench.json \
        --baseline benchmarks/results/rpc_baseline.json

Regenerate the baseline after an intentional protocol change with
``--update`` (then commit the new JSON alongside the change).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

# fewer matched metrics than this means the comparison is vacuous (wrong
# mode, truncated results file): fail loudly instead of passing silently
MIN_MATCHED = 10


def extract(rows: List[dict]) -> Dict[str, float]:
    """Flatten benchmark rows into gated metric keys -> RPC-count values."""
    out: Dict[str, float] = {}
    for r in rows:
        bench = r.get("bench")
        if bench == "fig3_latency":
            key = f"fig3/{r['system']}/{r['size']}B/crit_per_access"
            out[key] = r["critical_rpcs_per_access"]
        elif bench == "fig5_batch":
            bs = r.get("batch_size")
            tag = "nobatch" if bs is None else f"bs{bs}"
            key = f"fig5/{r['system']}/{tag}/n{r['n_files']}/critical_rpcs"
            out[key] = r["critical_rpcs"]
        elif bench == "fig6_write":
            key = f"fig6/{r['system']}/n{r['n_files']}/crit_per_file"
            out[key] = r["crit_rpcs_per_file"]
        elif bench == "fig7_readcache":
            key = f"fig7/{r['system']}/n{r['n_files']}"
            out[key + "/warm_crit_per_read"] = r["warm_crit_per_read"]
            out[key + "/cold_crit_per_read"] = r["cold_crit_per_read"]
        elif bench == "fig8_stripe" and r.get("mode") == "streaming":
            key = f"fig8/{r['system']}/h{r['hosts']}/streaming"
            out[key + "/crit_per_pass"] = r["crit_rpcs_per_pass"]
            # gated as a DEFICIT (4 - hosts touched) because regressions
            # here point down: fewer hosts reached means the scatter-gather
            # quietly collapsed onto fewer servers, and the gate only fails
            # on values ABOVE the committed ceiling
            out[key + "/fanout_deficit"] = 4 - r["fanout_hosts"]
        elif bench == "fig8_stripe" and r.get("mode") == "scrub":
            # chunk-hygiene gates, all exact counts.  Shortfalls are gated
            # as DEFICITS (expected - observed, ceiling 0) so a scrubber
            # that stops reaping/clipping FAILS rather than "improving";
            # the raw epoch_rejects ceiling additionally catches a retry
            # storm, and the residual/debt metrics pin "a second pass
            # finds nothing" — a future chunk leak moves one of these
            # above 0 and the gate, not just the docs, regresses.
            key = "fig8/buffetfs/scrub"
            out[key + "/orphan_deficit"] = (
                r["orphans_expected"] - r["orphans_reaped"])
            out[key + "/clip_deficit_bytes"] = (
                r["clip_bytes_expected"] - r["bytes_clipped"])
            out[key + "/epoch_reject_deficit"] = (
                r["epoch_rejects_expected"] - r["epoch_rejects"])
            out[key + "/epoch_rejects"] = r["epoch_rejects"]
            out[key + "/residual_orphans"] = r["residual_orphans"]
            out[key + "/residual_bytes_clipped"] = r["residual_bytes_clipped"]
            out[key + "/reap_failures_after_scrub"] = (
                r["reap_failures_after_scrub"])
            out[key + "/scrub_errors"] = r["scrub_errors"]
        elif bench == "rpc_table":
            key = f"rpc/{r['system']}/{r['op']}"
            out[key + "/warm_critical"] = r["warm_critical"]
            out[key + "/cold_critical"] = r["cold_critical"]
        elif bench == "fig10_mlstack":
            # bytes-per-op ceilings alongside the RPC-count gates: frame
            # sizes are exact functions of the wire format (fixed-width
            # slots, blake2s placement), so a header that grows — or a
            # code path that starts re-sending / re-encoding — fails here
            # deterministically, load-independent
            mode = r.get("mode")
            if mode == "wire":
                out[f"fig10/wire/{r['verb']}/bin_bytes"] = r["bin_bytes"]
            elif mode == "tcp":
                out["fig10/tcp/bytes_sent_per_op"] = r["bytes_sent_per_op"]
                out["fig10/tcp/bytes_recv_per_op"] = r["bytes_recv_per_op"]
            elif mode == "ckpt":
                key = f"fig10/ckpt/{r['phase']}"
                out[key + "/crit_rpcs"] = r["crit_rpcs"]
                out[key + "/rpcs"] = r["rpcs"]
                out[key + "/bytes_sent"] = r["bytes_sent"]
                out[key + "/bytes_recv"] = r["bytes_recv"]
            elif mode == "ingest":
                out["fig10/ingest/crit_rpcs"] = r["crit_rpcs"]
                out["fig10/ingest/rpcs"] = r["rpcs"]
                out["fig10/ingest/bytes_sent_per_sample"] = (
                    r["bytes_sent_per_sample"])
                out["fig10/ingest/bytes_recv"] = r["bytes_recv"]
        elif bench == "fig11_failover":
            # failover health: every metric is a count that should be
            # ZERO (errors, corrupt files, forced breaks, residual lag)
            # or a DEFICIT of an expected event (redirect, fence,
            # wait-out) — shortfalls point down, so they're inverted into
            # deficits to fail a ceiling-only gate
            mode = r.get("mode")
            key = f"fig11/{mode}"
            out[key + "/lease_breaks_forced"] = r["lease_breaks_forced"]
            if mode == "warm_lease":
                out[key + "/warm_crit_per_read"] = r["warm_crit_per_read"]
                out[key + "/lease_expiries"] = r["lease_expiries"]
                out[key + "/repl_lag_after"] = r["repl_lag_after"]
            elif mode == "failover":
                out[key + "/client_errors"] = r["client_errors"]
                out[key + "/data_bad"] = r["data_bad"]
                out[key + "/redirect_deficit"] = max(
                    0, 1 - r["failover_redirects"])
                out[key + "/fence_deficit"] = max(0, 1 - r["promote_waits"])
                out[key + "/repl_lag_after"] = r["repl_lag_after"]
            elif mode == "ttl_waitout":
                out[key + "/waitout_deficit"] = max(
                    0, 1 - r["lease_ttl_waits"])
                out[key + "/expired_drop_deficit"] = max(
                    0, 1 - r["lease_expired_drops"])
                out[key + "/stale_reads"] = r["stale_reads"]
                out[key + "/revoke_rpcs_to_client"] = (
                    r["revoke_rpcs_to_client"])
        elif bench == "fig13_durability":
            # replication durability: zero ceilings for anything a user
            # would see (errors, corrupt reads, forced lease breaks,
            # residual under-replication) plus DEFICITS of the expected
            # replication events — a hedge that stops firing, a read that
            # stops failing over, or a scrub that stops repairing fails
            # the ceiling-only gate instead of "improving" to zero
            mode = r.get("mode")
            key = f"fig13/{mode}"
            out[key + "/lease_breaks_forced"] = r["lease_breaks_forced"]
            out[key + "/client_errors"] = r["client_errors"]
            out[key + "/data_bad"] = r["data_bad"]
            if mode == "kill_stripe":
                out[key + "/failover_deficit"] = max(
                    0, 1 - r["read_failovers"])
                out[key + "/hedged_reads"] = r["hedged_reads"]
            elif mode == "slow_replica":
                out[key + "/hedge_deficit"] = max(0, 1 - r["hedged_reads"])
                out[key + "/hedge_win_deficit"] = max(
                    0, 1 - r["hedge_wins"])
            elif mode == "scrub_repair":
                out[key + "/under_replicated_deficit"] = max(
                    0, 1 - r["under_replicated_first"])
                out[key + "/repair_deficit"] = max(
                    0, 1 - r["repaired_chunks"])
                out[key + "/under_replicated_after"] = (
                    r["under_replicated_after"])
        elif bench == "fig12_perms":
            # serve-yourself permission gates: warm ACL/group checks and
            # denials must stay RPC-free (raw zero ceilings), expected
            # events (granted reads, denials, revoke-driven denials) are
            # inverted into DEFICITS so a grant that stops admitting — or
            # a revoke that stops denying — fails the ceiling-only gate
            mode = r.get("mode")
            key = f"fig12/{mode}"
            out[key + "/lease_breaks_forced"] = r["lease_breaks_forced"]
            if mode == "warm_grants":
                out[key + "/warm_crit_rpcs"] = r["warm_crit_rpcs"]
                out[key + "/warm_group_fetch_rpcs"] = (
                    r["warm_group_fetch_rpcs"])
                out[key + "/group_fetch_rpcs"] = r["group_fetch_rpcs"]
                out[key + "/granted_deficit"] = (
                    r["granted_expected"] - r["granted_ok"])
                out[key + "/denied_deficit"] = (
                    r["denied_expected"] - r["denied"])
                out[key + "/repl_lag_after"] = r["repl_lag_after"]
            elif mode == "revoke":
                out[key + "/stale_allows"] = r["stale_allows"]
                out[key + "/allowed_deficit"] = (
                    r["allowed_expected"] - r["allowed_before"])
                out[key + "/acl_deny_deficit"] = (
                    r["acl_denies_expected"] - r["denied_after_acl_revoke"])
                out[key + "/group_deny_deficit"] = (
                    r["group_denies_expected"]
                    - r["denied_after_group_revoke"])
    return out


def compare(actual: Dict[str, float], expected: Dict[str, float]) -> int:
    failures: List[str] = []
    matched = 0
    for key in sorted(expected):
        ceiling = expected[key]
        got = actual.get(key)
        if got is None:
            failures.append(f"metric vanished from results: {key}")
            continue
        matched += 1
        if got > ceiling + 1e-9:
            failures.append(f"{key}: {got} > baseline {ceiling}")
        elif got < ceiling - 1e-9:
            print(f"improved: {key}: {got} < baseline {ceiling}")
    for key in sorted(set(actual) - set(expected)):
        print(f"unbaselined (ignored): {key} = {actual[key]}")
    if matched < MIN_MATCHED:
        failures.append(
            f"only {matched} baselined metrics matched (< {MIN_MATCHED}): "
            "wrong mode or truncated results?"
        )
    for f in failures:
        print(f"REGRESSION: {f}", file=sys.stderr)
    if not failures:
        print(f"bench-regression gate: {matched} metrics within baseline")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--actual", default="benchmarks/results/paper_bench.json")
    ap.add_argument("--baseline", default="benchmarks/results/rpc_baseline.json")
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline from the actual results instead",
    )
    args = ap.parse_args()

    with open(args.actual) as f:
        actual = extract(json.load(f))
    if args.update:
        blob = {"mode": "quick", "expected": actual}
        with open(args.baseline, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"baseline rewritten: {len(actual)} metrics -> {args.baseline}")
        return
    with open(args.baseline) as f:
        expected = json.load(f)["expected"]
    sys.exit(compare(actual, expected))


if __name__ == "__main__":
    main()
