"""Shared helpers for the BuffetFS paper benchmarks.

All three systems (BuffetFS, Lustre-Normal, Lustre-DoM) run over identical
BServer storage and the same InProcTransport with the calibrated latency
model (200us RTT / 20us service / ~5.5GiB/s), so differences measure the
PROTOCOL — the paper's variable.  Each test group regenerates its file set
(paper §4: "we regenerate the files set for each test").
"""
from __future__ import annotations

import shutil
import tempfile
import time
from contextlib import contextmanager
from typing import Callable, Iterator, List, Tuple

from repro.core import (BAgent, BLib, BuffetCluster, LustreDoMClient,
                        LustreNormalClient)
from repro.core.perms import O_CREAT, O_RDONLY, O_TRUNC, O_WRONLY
from repro.core.transport import LatencyModel

# calibrated to the paper's testbed scale: Lustre 2.10 over IB with
# HDD-RAID6 storage serves a small-file metadata/data op in O(1ms)
# (paper Fig. 3 latencies are milliseconds).  ms-scale injection also keeps
# host-Python overhead (~0.1ms/op on this container) second-order.
DEFAULT_LATENCY = LatencyModel(rtt_us=1500.0, per_mib_us=2000.0,
                               service_us=800.0)


@contextmanager
def fresh_cluster(n_servers: int = 4, latency: LatencyModel = DEFAULT_LATENCY,
                  stripe_count: int = 1, stripe_size: int = 1 << 20
                  ) -> Iterator[BuffetCluster]:
    root = tempfile.mkdtemp(prefix="buffet_bench_")
    cluster = BuffetCluster(root_dir=root, n_servers=n_servers,
                            latency=latency, stripe_count=stripe_count,
                            stripe_size=stripe_size)
    try:
        yield cluster
    finally:
        cluster.shutdown()
        shutil.rmtree(root, ignore_errors=True)


def mkfiles(cluster: BuffetCluster, n_files: int, size: int,
            n_dirs: int = 1, prefix: str = "/bench",
            system: str = "buffetfs") -> List[str]:
    """Create the benchmark file set through a zero-latency admin path.

    For the Lustre baselines the ENTIRE namespace must live on the MDS
    (host 0), so the file set is created through the baseline's own client
    (MDS-rooted mkdir/create); BuffetFS uses its decentralized placement.
    """
    lat = cluster.transport.latency
    cluster.transport.latency = LatencyModel(0, 0, 0)
    payload = b"x" * size
    paths = []
    if system == "buffetfs":
        agent = BAgent(cluster)
        lib = BLib(agent)
        for d in range(n_dirs):
            dname = f"{prefix}/d{d:03d}"
            lib.makedirs(dname)
            for i in range(n_files // n_dirs):
                p = f"{dname}/f{i:05d}"
                lib.write_file(p, payload)
                paths.append(p)
        agent.drain()
        agent.shutdown()
    else:
        import errno as _errno
        from repro.core.inode import Inode
        from repro.core.wire import Message, MsgType
        c = LustreNormalClient(cluster)
        try:
            c.mkdir(prefix)
        except OSError as e:
            if e.errno != _errno.EEXIST:
                raise
        # data placement: DoM keeps small-file data ON the MDS (host 0);
        # Lustre-Normal stripes data objects to the OSSes (hosts 1..n-1)
        oss_hosts = ([0] if system == "lustre-dom" or cluster.n_servers == 1
                     else list(range(1, cluster.n_servers)))
        osc = 0
        for d in range(n_dirs):
            dname = f"{prefix}/d{d:03d}"
            try:
                c.mkdir(dname)
            except OSError as e:
                if e.errno != _errno.EEXIST:
                    raise
            parent_fid, _ = c._resolve_parent(dname + "/x")
            for i in range(n_files // n_dirs):
                p = f"{dname}/f{i:05d}"
                host = oss_hosts[osc % len(oss_hosts)]
                osc += 1
                r1 = c._rpc(host, Message(MsgType.MKNOD_OBJ, {
                    "is_dir": False, "mode": 0o644, "uid": 0, "gid": 0}))
                c._rpc(0, Message(MsgType.LINK_DENTRY, {
                    "parent": parent_fid, "name": p.rsplit("/", 1)[1],
                    "ino": r1.header["ino"], "perm": r1.header["perm"]}))
                fid = Inode.unpack(r1.header["ino"]).file_id
                c._rpc(host, Message(MsgType.WRITE,
                                     {"file_id": fid, "offset": 0}, payload))
                paths.append(p)
        c.drain()
        c.shutdown()
    cluster.transport.latency = lat
    return paths


def make_client(kind: str, cluster: BuffetCluster):
    if kind == "buffetfs":
        agent = BAgent(cluster)
        return agent, agent
    if kind == "buffetfs-wb":
        agent = BAgent(cluster, write_behind=True)
        return agent, agent
    if kind == "buffetfs-cache":
        # lease-consistent client page cache: warm reads cost zero RPCs
        agent = BAgent(cluster, read_cache=True)
        return agent, agent
    if kind == "buffetfs-ra":
        # page cache + sequential-read detector issuing async readahead
        agent = BAgent(cluster, read_cache=True, readahead=True,
                       cache_budget=64 * 1024 * 1024,
                       readahead_window=4 * 1024 * 1024)
        return agent, agent
    if kind == "lustre-normal":
        c = LustreNormalClient(cluster)
        return c, c
    if kind == "lustre-dom":
        c = LustreDoMClient(cluster)
        return c, c
    raise KeyError(kind)


def access_file(client, path: str, *, read: bool = True,
                payload: bytes = b"") -> None:
    """The paper's measured unit: open() + read()/write() + close()."""
    if read:
        fd = client.open(path, O_RDONLY)
        client.read(fd)
    else:
        fd = client.open(path, O_WRONLY | O_CREAT | O_TRUNC)
        client.write(fd, payload)
    client.close(fd)


def timeit_us(fn: Callable[[], None], warmup: int = 2, iters: int = 10
              ) -> Tuple[float, float]:
    """Median per-call latency in us (median suppresses scheduler-wakeup
    outliers from the async-close worker thread on a single core)."""
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    samples.sort()
    mid = samples[len(samples) // 2]
    return mid * 1e6, float(iters)
