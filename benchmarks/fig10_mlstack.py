"""Figure 10 (extension): the ML I/O stack over the binary wire fast path.

Three sections, cheapest first:

  wire     microbenchmark of the header codec alone: representative hot-verb
           headers (READ/WRITE/CHUNK_WRITE requests, READ/OK responses with
           lease+wseq+epoch, an EPOCHSTALE ERROR) encoded+decoded through
           the binary (v2) codec vs the legacy JSON (v1) codec.  Reports
           ns/op and bytes/op per verb plus the aggregate speedup — the
           acceptance bar is >= 3x.  Bytes/op is deterministic and gated by
           check_regression; ns/op is wall-clock and informational, but the
           RATIO is load-insensitive (both codecs run on the same core).
  tcp      smoke of the vectored-send path: one real-socket round trip per
           op through TCPTransport (socket.sendmsg scatter/gather framing,
           memoryview receive) with a 1 MiB payload each way, verifying the
           per-verb encode_ns/decode_ns counters actually tick and that
           frame sizes are exact — bytes per op is deterministic and gated.
  mlstack  the end-to-end workload the ROADMAP points at BuffetFS: a
           CheckpointManager save/restore (heavy sequential striped writes
           + reads through ckpt/manager.py) and a DataPipeline shuffle
           ingest (many small reads through data/pipeline.py) on one
           InProc cluster.  Hedging and caching are off and the sampler is
           finite, so critical-path RPC counts and bytes are EXACT and
           gated; per-verb serialization time comes out zero here (the
           in-proc transport ships Message objects by reference), which is
           itself asserted — protocol cost and codec cost stay separable.

    PYTHONPATH=src python -m benchmarks.fig10_mlstack [--quick] [--wire-only]
"""
from __future__ import annotations

import time
from typing import Dict, Iterator, List, Sequence

from repro.core import BAgent, BLib, Message, MsgType
from repro.core.transport import LatencyModel, RpcStats, TCPTransport
from repro.core.wire import decode, encode, encode_json, ok

from .common import fresh_cluster

WIRE_ITERS = 100_000
CKPT_LEAVES = 6           # model "layers" in the checkpoint tree
CKPT_ROWS = 4096          # rows per leaf (axis 0, split over parts)
CKPT_COLS = 64            # float32 => 1 MiB per leaf
INGEST_SAMPLES = 64
INGEST_BATCH = 16
SEQ_LEN = 64

# Representative hot-verb headers, exactly as the client/server build them.
# ns/op and bytes/op are measured on the HEADER path (empty payload): the
# payload crosses both codecs untouched, so this isolates what the binary
# format changed.
WIRE_CASES = (
    ("READ_req", MsgType.READ,
     {"file_id": 123456, "offset": 1 << 20, "length": 65536, "ver": 3,
      "_rid": 987654}),
    ("READ_resp", MsgType.OK,
     {"eof": False, "size": 1 << 25, "wseq": 17, "epoch": 2, "lease": True,
      "_rid": 987654}),
    ("WRITE_req", MsgType.WRITE,
     {"file_id": 123456, "offset": 1 << 20, "ver": 3, "_rid": 987654}),
    ("CHUNK_WRITE_req", MsgType.CHUNK_WRITE,
     {"home": 2, "file_id": 123456, "index": 7, "offset": 4096, "epoch": 5,
      "ver": 3, "_rid": 42}),
    ("ERROR_epochstale", MsgType.ERROR,
     {"errno": 1064, "epoch": 9, "_rid": 11}),
)


def _ns_per_op(fn, iters: int) -> float:
    fn()  # warm the codec caches; the steady state is what ships
    t0 = time.perf_counter_ns()
    for _ in range(iters):
        fn()
    return (time.perf_counter_ns() - t0) / iters


def run_wire(iters: int = WIRE_ITERS) -> List[Dict]:
    rows: List[Dict] = []
    tot_json = tot_bin = 0.0
    for name, mt, header in WIRE_CASES:
        fj = encode_json(mt, header)
        fb = encode(mt, header)
        t2, h2, _ = decode(fb)
        assert t2 is mt and h2 == header, "binary codec round-trip broke"
        ns_json = _ns_per_op(lambda mt=mt, h=header:
                             decode(encode_json(mt, h)), iters)
        ns_bin = _ns_per_op(lambda mt=mt, h=header:
                            decode(encode(mt, h)), iters)
        tot_json += ns_json
        tot_bin += ns_bin
        rows.append({"bench": "fig10_mlstack", "mode": "wire", "verb": name,
                     "json_ns": round(ns_json, 1), "bin_ns": round(ns_bin, 1),
                     "speedup": round(ns_json / ns_bin, 2),
                     "json_bytes": len(fj), "bin_bytes": len(fb)})
    rows.append({"bench": "fig10_mlstack", "mode": "wire",
                 "verb": "aggregate",
                 "json_ns": round(tot_json, 1), "bin_ns": round(tot_bin, 1),
                 "speedup": round(tot_json / tot_bin, 2),
                 "json_bytes": sum(r["json_bytes"] for r in rows),
                 "bin_bytes": sum(r["bin_bytes"] for r in rows)})
    return rows


def run_tcp(payload_mib: int = 1, ops: int = 8) -> List[Dict]:
    """Round-trip `ops` bulk WRITEs over a real socket: exercises the
    sendmsg scatter/gather send on both directions and the memoryview
    receive path, and proves the per-verb serialization counters tick."""
    store: Dict[int, bytes] = {}

    def handler(msg: Message) -> Message:
        if msg.type is MsgType.WRITE:
            store[msg.header["file_id"]] = bytes(msg.payload)
            return ok({"written": len(msg.payload)})
        if msg.type is MsgType.READ:
            return ok({"eof": True}, store.get(msg.header["file_id"], b""))
        return ok()

    tr = TCPTransport()
    addr = tr.serve("127.0.0.1:0", handler)
    stats = RpcStats()
    blob = b"\xa5" * (payload_mib << 20)
    try:
        t0 = time.perf_counter()
        for i in range(ops):
            w = tr.request(addr, Message(
                MsgType.WRITE, {"file_id": i, "offset": 0}, blob),
                stats=stats)
            assert w.header["written"] == len(blob)
            r = tr.request(addr, Message(
                MsgType.READ, {"file_id": i, "offset": 0,
                               "length": len(blob)}), stats=stats)
            assert bytes(r.payload) == blob
        dt = time.perf_counter() - t0
    finally:
        tr.shutdown(addr)
    snap = stats.snapshot()
    moved_mib = 2 * ops * payload_mib  # payload out on WRITE, back on READ
    return [{"bench": "fig10_mlstack", "mode": "tcp", "ops": 2 * ops,
             "payload_mib": payload_mib,
             "bytes_sent_per_op": snap["bytes_sent"] // (2 * ops),
             "bytes_recv_per_op": snap["bytes_recv"] // (2 * ops),
             "mb_per_s": round(moved_mib / dt, 1),
             "encode_ns_total": sum(snap["encode_ns"].values()),
             "decode_ns_total": sum(snap["decode_ns"].values())}]


class _FiniteSampler:
    """A pre-materialized epoch of index batches: the pipeline's producer
    stops by itself after the last batch, so the measured RPC totals are
    exact (an infinite sampler would keep prefetching past the snapshot)."""

    def __init__(self, batches: Sequence[List[int]]) -> None:
        self.batches = batches

    def __iter__(self) -> Iterator[List[int]]:
        return iter(self.batches)


def run_mlstack() -> List[Dict]:
    import numpy as np

    from repro.ckpt.manager import CheckpointManager
    from repro.data.dataset import BuffetDataset
    from repro.data.pipeline import DataPipeline

    rows: List[Dict] = []
    # zero injected latency: this section measures RPC counts and bytes,
    # not simulated network time — and the counts are placement-independent
    # (fixed-size header slots, blake2s placement), hence exactly gateable
    with fresh_cluster(n_servers=4, latency=LatencyModel(0, 0, 0),
                       stripe_count=4, stripe_size=256 * 1024) as cluster:
        # --- checkpoint save/restore: heavy sequential striped writes ----
        # fixed client_id: the default embeds a process-global counter, so
        # its JSON-encoded length in CLOSE / deferred-open headers would
        # depend on how many agents earlier benchmarks created — pinning it
        # keeps the gated byte metrics run-order independent
        agent = BAgent(cluster, client_id="fig10-ckpt")  # sync commits
        lib = BLib(agent)
        mgr = CheckpointManager(lib, "fig10", parts=2, keep_last=2)
        tree = {f"layer{i}": np.arange(CKPT_ROWS * CKPT_COLS,
                                       dtype=np.float32).reshape(
                                           CKPT_ROWS, CKPT_COLS) + i
                for i in range(CKPT_LEAVES)}
        ckpt_bytes = sum(a.nbytes for a in tree.values())

        agent.stats.reset()
        t0 = time.perf_counter()
        mgr.save(1, tree, block=True)
        agent.drain()
        save_s = time.perf_counter() - t0
        snap = agent.stats.snapshot()
        rows.append({"bench": "fig10_mlstack", "mode": "ckpt",
                     "phase": "save", "payload_bytes": ckpt_bytes,
                     "crit_rpcs": snap["critical_path"],
                     "rpcs": snap["total"], "subops": snap["subops"],
                     "bytes_sent": snap["bytes_sent"],
                     "bytes_recv": snap["bytes_recv"],
                     "bytes_per_payload_byte": round(
                         snap["bytes_sent"] / ckpt_bytes, 3),
                     "serialization_ns": sum(snap["encode_ns"].values())
                     + sum(snap["decode_ns"].values()),
                     "mb_per_s": round(ckpt_bytes / (1 << 20) / save_s, 1)})

        agent.stats.reset()
        t0 = time.perf_counter()
        step, out = mgr.restore(like=tree)
        restore_s = time.perf_counter() - t0
        assert step == 1
        for k, a in tree.items():
            assert np.array_equal(out[k], a), f"restore corrupted {k}"
        snap = agent.stats.snapshot()
        rows.append({"bench": "fig10_mlstack", "mode": "ckpt",
                     "phase": "restore", "payload_bytes": ckpt_bytes,
                     "crit_rpcs": snap["critical_path"],
                     "rpcs": snap["total"], "subops": snap["subops"],
                     "bytes_sent": snap["bytes_sent"],
                     "bytes_recv": snap["bytes_recv"],
                     "bytes_per_payload_byte": round(
                         snap["bytes_recv"] / ckpt_bytes, 3),
                     "serialization_ns": sum(snap["encode_ns"].values())
                     + sum(snap["decode_ns"].values()),
                     "mb_per_s": round(ckpt_bytes / (1 << 20) / restore_s,
                                       1)})
        agent.shutdown()

        # --- data pipeline shuffle ingest: many small reads --------------
        builder = BAgent(cluster, client_id="fig10-build")
        rng = np.random.default_rng(0)
        samples = [rng.integers(0, 1000, size=SEQ_LEN + 1).astype(np.int32)
                   for _ in range(INGEST_SAMPLES)]
        ds = BuffetDataset.build(BLib(builder), samples, name="fig10",
                                 shard_size=INGEST_SAMPLES // 4)
        builder.drain()
        builder.shutdown()

        reader = BAgent(cluster, client_id="fig10-read")  # no cache/hedging
        ds_r = BuffetDataset(BLib(reader), name="fig10")
        n_steps = INGEST_SAMPLES // INGEST_BATCH
        batches = [list(range(s * INGEST_BATCH, (s + 1) * INGEST_BATCH))
                   for s in range(n_steps)]
        pipe = DataPipeline(ds_r, _FiniteSampler(batches), seq_len=SEQ_LEN,
                            prefetch=2, io_threads=4)
        reader.stats.reset()
        t0 = time.perf_counter()
        got = 0
        for batch in pipe:
            assert batch["tokens"].shape == (INGEST_BATCH, SEQ_LEN)
            got += 1
            if got == n_steps:
                break
        ingest_s = time.perf_counter() - t0
        pipe.stop()
        reader.drain()
        snap = reader.stats.snapshot()
        rows.append({"bench": "fig10_mlstack", "mode": "ingest",
                     "samples": INGEST_SAMPLES, "batches": n_steps,
                     "crit_rpcs": snap["critical_path"],
                     "rpcs": snap["total"],
                     "bytes_sent": snap["bytes_sent"],
                     "bytes_recv": snap["bytes_recv"],
                     "bytes_sent_per_sample":
                         snap["bytes_sent"] // INGEST_SAMPLES,
                     "crit_per_sample": round(
                         snap["critical_path"] / INGEST_SAMPLES, 3),
                     "serialization_ns": sum(snap["encode_ns"].values())
                     + sum(snap["decode_ns"].values()),
                     "samples_per_s": round(INGEST_SAMPLES / ingest_s, 1)})
        reader.shutdown()
    return rows


def run(wire_iters: int = WIRE_ITERS, wire_only: bool = False) -> List[Dict]:
    rows = run_wire(wire_iters)
    if not wire_only:
        rows += run_tcp()
        rows += run_mlstack()
    return rows


def verdict(rows: List[Dict]) -> List[str]:
    out: List[str] = []
    agg = next((r for r in rows if r.get("mode") == "wire"
                and r["verb"] == "aggregate"), None)
    if agg:
        status = "PASS" if agg["speedup"] >= 3.0 else "FAIL"
        out.append(f"{status}: hot-verb header encode+decode "
                   f"{agg['speedup']}x vs JSON (bar: >=3x), "
                   f"{agg['bin_bytes']}B vs {agg['json_bytes']}B")
    for r in rows:
        if r.get("mode") == "wire" and r["verb"] != "aggregate":
            status = "PASS" if r["bin_bytes"] <= r["json_bytes"] else "FAIL"
            out.append(f"{status}: {r['verb']} binary header "
                       f"{r['bin_bytes']}B <= JSON {r['json_bytes']}B "
                       f"({r['speedup']}x)")
    tcp = next((r for r in rows if r.get("mode") == "tcp"), None)
    if tcp:
        status = ("PASS" if tcp["encode_ns_total"] > 0
                  and tcp["decode_ns_total"] > 0 else "FAIL")
        out.append(f"{status}: TCP sendmsg path ticks serialization "
                   f"counters (enc {tcp['encode_ns_total']}ns, "
                   f"dec {tcp['decode_ns_total']}ns) at "
                   f"{tcp['mb_per_s']}MB/s")
    save = next((r for r in rows if r.get("mode") == "ckpt"
                 and r["phase"] == "save"), None)
    if save:
        status = ("PASS" if save["bytes_per_payload_byte"] < 1.1
                  and save["serialization_ns"] == 0 else "FAIL")
        out.append(f"{status}: ckpt save wire overhead "
                   f"{save['bytes_per_payload_byte']}x payload, "
                   f"{save['crit_rpcs']} critical RPCs, in-proc "
                   f"serialization {save['serialization_ns']}ns (expected 0)")
    ing = next((r for r in rows if r.get("mode") == "ingest"), None)
    if ing:
        status = "PASS" if ing["crit_per_sample"] <= 1.25 else "FAIL"
        out.append(f"{status}: ingest {ing['crit_per_sample']} critical "
                   f"RPCs/sample (warm-dir amortized; bar <=1.25)")
    return out


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--wire-only", action="store_true",
                    help="codec microbenchmark only (CI smoke)")
    args = ap.parse_args()
    rows = run(wire_iters=20_000 if args.quick else WIRE_ITERS,
               wire_only=args.wire_only)
    for r in rows:
        print(r)
    for line in verdict(rows):
        print(line)


if __name__ == "__main__":
    main()
