"""RPC-count table (paper §1/§3): critical-path and async RPCs per
open-read-close and open-write-close sequence, per system, cold vs warm
directory cache.  This is the paper's mechanism stated as a table:
Lustre >= 3 round trips (close async) -> BuffetFS exactly 1 on the
critical path."""
from __future__ import annotations

import time
from typing import Dict, List

from .common import access_file, fresh_cluster, make_client, mkfiles
from repro.core.transport import LatencyModel

SYSTEMS = ("buffetfs", "lustre-normal", "lustre-dom")


def run() -> List[Dict]:
    rows = []
    for system in SYSTEMS:
        for op in ("read", "write"):
            with fresh_cluster(latency=LatencyModel(0, 0, 0)) as cluster:
                paths = mkfiles(cluster, n_files=4, size=4096, system=system)
                client, owner = make_client(system, cluster)
                # cold: first access (includes directory fetches)
                owner.stats.reset()
                access_file(client, paths[0], read=(op == "read"),
                            payload=b"y" * 4096)
                _drain(client)
                cold = owner.stats.snapshot()
                # warm: directory cache hot
                owner.stats.reset()
                access_file(client, paths[1], read=(op == "read"),
                            payload=b"y" * 4096)
                _drain(client)
                warm = owner.stats.snapshot()
                rows.append({
                    "bench": "rpc_table", "system": system, "op": op,
                    "cold_critical": cold["critical_path"],
                    "cold_async": cold["async_offpath"],
                    "warm_critical": warm["critical_path"],
                    "warm_async": warm["async_offpath"],
                })
                if hasattr(client, "shutdown"):
                    client.shutdown()
    return rows


def _drain(client) -> None:
    if hasattr(client, "drain"):
        client.drain()
    time.sleep(0.01)


def main() -> None:
    for r in run():
        print(f"rpc,{r['system']},{r['op']},cold={r['cold_critical']}"
              f"+{r['cold_async']}async,warm={r['warm_critical']}"
              f"+{r['warm_async']}async")


if __name__ == "__main__":
    main()
