"""Figure 7 (extension): cold vs warm re-read of a many-small-file tree —
the lease-consistent client page cache vs per-read RPCs.

The measured unit is the paper's open + read + close sequence over a tree
of small files, executed once cold (empty caches) and then re-read in
repeated warm passes:

  buffetfs-cache   READ responses fill the agent's block cache under a
                   server-granted read lease => every warm access is served
                   locally: 0 critical-path RPCs per warm read
  buffetfs         no data cache: 1 critical READ per warm access (the
                   paper's baseline "exactly one RPC" behavior)
  lustre-normal    blocking MDS open + OSS read per access, warm or not
  lustre-dom       MDS open+inline-read: 1 RPC per access, warm or not
                   (the inline payload is bound to one open(), not a cache)

Target: ~0 critical-path RPCs per warm read for the cached agent (vs >= 1
for everything else) and a clear warm-pass wall-clock win over both Lustre
baselines.

    PYTHONPATH=src python -m benchmarks.fig7_readcache [--quick]
"""

from __future__ import annotations

import time
from typing import Dict, List, Sequence

from repro.core.transport import LatencyModel

from .common import access_file, fresh_cluster, make_client, mkfiles

# same ms-scale calibration as the other paper benchmarks (common.py)
FIG7_LATENCY = LatencyModel(rtt_us=1500.0, per_mib_us=2000.0, service_us=800.0)

FILE_COUNTS = (256, 1024)
SYSTEMS = ("buffetfs-cache", "buffetfs", "lustre-normal", "lustre-dom")
FILE_SIZE = 4096
N_DIRS = 8
WARM_PASSES = 2


def _drain(client) -> None:
    if hasattr(client, "drain"):
        client.drain()


def run(
    file_counts: Sequence[int] = FILE_COUNTS,
    latency: LatencyModel = FIG7_LATENCY,
    systems: Sequence[str] = SYSTEMS,
    warm_passes: int = WARM_PASSES,
) -> List[Dict]:
    rows: List[Dict] = []
    for n_files in file_counts:
        for system in systems:
            fs_kind = system if system.startswith("lustre") else "buffetfs"
            with fresh_cluster(latency=latency) as cluster:
                paths = mkfiles(
                    cluster,
                    n_files=n_files,
                    size=FILE_SIZE,
                    n_dirs=N_DIRS,
                    system=fs_kind,
                )
                client, owner = make_client(system, cluster)
                owner.stats.reset()
                t0 = time.perf_counter()
                for p in paths:
                    access_file(client, p)
                cold_s = time.perf_counter() - t0
                _drain(client)
                cold = owner.stats.snapshot()
                owner.stats.reset()
                t0 = time.perf_counter()
                for _ in range(warm_passes):
                    for p in paths:
                        access_file(client, p)
                warm_s = time.perf_counter() - t0
                _drain(client)
                warm = owner.stats.snapshot()
                n_warm = n_files * warm_passes
                cold_cpr = round(cold["critical_path"] / n_files, 4)
                warm_cpr = round(warm["critical_path"] / n_warm, 4)
                has_cache = hasattr(client, "cache_stats")
                cache = client.cache_stats() if has_cache else None
                rows.append(
                    {
                        "bench": "fig7_readcache",
                        "system": system,
                        "n_files": n_files,
                        "warm_passes": warm_passes,
                        "file_size": FILE_SIZE,
                        "cold_seconds": round(cold_s, 3),
                        "warm_seconds": round(warm_s, 3),
                        "cold_critical_rpcs": cold["critical_path"],
                        "warm_critical_rpcs": warm["critical_path"],
                        "cold_crit_per_read": cold_cpr,
                        "warm_crit_per_read": warm_cpr,
                        "cache": cache,
                    }
                )
                if hasattr(client, "shutdown"):
                    client.shutdown()
    return rows


def verdict(rows: List[Dict], n_files: int) -> List[str]:
    """Acceptance statement: the cached agent serves warm reads with ~0
    critical-path RPCs while every other system pays >= 1 per read, and its
    warm pass beats both Lustre baselines on wall-clock time."""
    by = {r["system"]: r for r in rows if r["n_files"] == n_files}
    rc = by.get("buffetfs-cache")
    lines: List[str] = []
    if rc is not None:
        ok = rc["warm_crit_per_read"] <= 0.01
        lines.append(
            f"n={n_files}: buffetfs-cache warm {rc['warm_crit_per_read']} "
            f"crit RPCs/read ({'PASS' if ok else 'FAIL'} ~0)"
        )
    for system in ("buffetfs", "lustre-normal", "lustre-dom"):
        r = by.get(system)
        if r is not None:
            ok = r["warm_crit_per_read"] >= 1
            lines.append(
                f"n={n_files}: {system} warm {r['warm_crit_per_read']} "
                f"crit RPCs/read ({'PASS' if ok else 'FAIL'} >=1: no cache)"
            )
    ln, ld = by.get("lustre-normal"), by.get("lustre-dom")
    if rc is not None and ln is not None and ld is not None:
        lmin = min(ln["warm_seconds"], ld["warm_seconds"])
        ok = rc["warm_seconds"] < lmin
        lines.append(
            f"n={n_files}: warm pass {rc['warm_seconds']}s vs lustre "
            f"{ln['warm_seconds']}s / {ld['warm_seconds']}s "
            f"({'PASS' if ok else 'FAIL'} beats both baselines)"
        )
    return lines


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    counts = (128,) if args.quick else FILE_COUNTS
    rows = run(file_counts=counts)
    for r in rows:
        print(
            f"fig7,{r['system']},n={r['n_files']},"
            f"cold={r['cold_seconds']}s/{r['cold_crit_per_read']}rpc,"
            f"warm={r['warm_seconds']}s/{r['warm_crit_per_read']}rpc"
        )
    for n in counts:
        for line in verdict(rows, n):
            print(line)


if __name__ == "__main__":
    main()
