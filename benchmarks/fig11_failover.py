"""Fig 11: home-host failover and TTL-bounded read leases.

Three deterministic scenarios, each gated on RPC/counter arithmetic
(never wall-clock), matching the failover design's three claims:

  * warm_lease — with commit-log replication ENABLED, a cached client
    still serves warm reads under an unexpired lease at zero
    critical-path RPCs: log shipping rides entirely off the critical
    path, no grant expires mid-pass, and no lease is ever force-broken.
  * failover — kill a home host mid-workload, promote its standby on a
    background thread, and let a blocking read bridge the outage through
    the client's capped-backoff retry + config redirect.  Every byte
    written before the crash must read back intact afterwards with zero
    client-visible errors, the promoted authority's first mutation is
    fenced behind one lease TTL, and its own commit log drains to zero
    lag against the next standby along the ring.
  * ttl_waitout — partition a caching client's callback address so
    REVOKE_LEASE cannot be delivered: the server waits out the grant's
    TTL instead of force-breaking it, drops already-expired grants
    without any revoke RPC, and the client (whose clock runs AHEAD of
    the server's, having stamped t0 before the granting RPC left) never
    serves a stale block.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time
from typing import Dict, List

from repro.core import BAgent, BLib, BuffetCluster, Inode
from repro.core.failure import partitioned

# TTLs are scenario parameters, not sweep axes: warm passes must finish
# well inside WARM_TTL, while the fence/wait-out scenarios want a TTL
# short enough that one deliberate sleep stays cheap.
WARM_TTL_S = 30.0
FENCE_TTL_S = 0.3
WAITOUT_TTL_S = 0.4


def _pattern(i: int, size: int) -> bytes:
    return bytes((i * 7 + j) % 251 for j in range(size))


def _home(agent: BAgent, path: str) -> int:
    node, _ = agent._walk(path)
    return Inode.unpack(node.ino).host_id


def _sum_srv(cluster: BuffetCluster, attr: str) -> int:
    return sum(getattr(s, attr) for s in cluster.servers.values())


def _warm_lease(n_files: int, warm_passes: int, size: int) -> Dict:
    with tempfile.TemporaryDirectory() as root:
        cluster = BuffetCluster(root_dir=root, n_servers=4,
                                replication=True, lease_ttl_s=WARM_TTL_S)
        try:
            writer = BLib(BAgent(cluster))
            writer.makedirs("/warm")
            paths = [f"/warm/f{i:04d}" for i in range(n_files)]
            for i, p in enumerate(paths):
                writer.write_file(p, _pattern(i, size))

            reader = BAgent(cluster, read_cache=True)
            rlib = BLib(reader)
            reader.stats.reset()
            t0 = time.perf_counter()
            for p in paths:
                rlib.read_file(p)
            cold_s = time.perf_counter() - t0
            cold = reader.stats.snapshot()["critical_path"]

            reader.stats.reset()
            t0 = time.perf_counter()
            for _ in range(warm_passes):
                for p in paths:
                    rlib.read_file(p)
            warm_s = time.perf_counter() - t0
            warm = reader.stats.snapshot()["critical_path"]

            # replication is on the whole time: after a drain the shipped
            # log has fully converged without ever touching the read path
            lag = 0
            for srv in cluster.servers.values():
                srv.repl_drain()
                lag += srv.repl_stats().get("repl_lag", 0)
            cache = reader.cache_stats() or {}
            return {
                "bench": "fig11_failover",
                "mode": "warm_lease",
                "n_files": n_files,
                "warm_passes": warm_passes,
                "cold_seconds": round(cold_s, 3),
                "warm_seconds": round(warm_s, 3),
                "cold_crit_per_read": round(cold / n_files, 4),
                "warm_crit_per_read": round(
                    warm / (n_files * warm_passes), 4),
                "lease_expiries": cache.get("lease_expiries", 0),
                "lease_breaks_forced": _sum_srv(cluster,
                                                "lease_breaks_forced"),
                "repl_lag_after": lag,
            }
        finally:
            cluster.shutdown()


def _failover(n_files: int, size: int) -> Dict:
    with tempfile.TemporaryDirectory() as root:
        cluster = BuffetCluster(root_dir=root, n_servers=4,
                                replication=True, lease_ttl_s=FENCE_TTL_S)
        try:
            writer = BLib(BAgent(cluster))
            writer.makedirs("/bench")
            blobs: Dict[str, bytes] = {}
            for i in range(n_files):
                p = f"/bench/f{i:04d}"
                blobs[p] = _pattern(i, size)
                writer.write_file(p, blobs[p])
            for srv in cluster.servers.values():
                assert srv.repl_drain(), "replication lag stuck pre-crash"

            probe = sorted(blobs)[0]
            victim = _home(writer.agent, probe)
            reader = BAgent(cluster)
            rlib = BLib(reader)

            cluster.kill_server(victim)
            promoter = threading.Thread(
                target=lambda: (time.sleep(0.15), cluster.promote(victim)))
            promoter.start()
            client_errors = 0
            t0 = time.perf_counter()
            try:
                bridged = rlib.read_file(probe) == blobs[probe]
            except OSError:
                client_errors += 1
                bridged = False
            outage_bridge_s = time.perf_counter() - t0
            promoter.join()

            data_bad = 0
            for p, want in sorted(blobs.items()):
                try:
                    if rlib.read_file(p) != want:
                        data_bad += 1
                except OSError:
                    client_errors += 1
            if not bridged:
                data_bad += 1

            # first mutation against the promoted authority: fenced
            # behind one lease TTL so no pre-crash grant can outlive it
            try:
                rlib.write_file(probe, blobs[probe][::-1])
            except OSError:
                client_errors += 1
            promoted = cluster.servers[victim]
            promoted.repl_drain()
            return {
                "bench": "fig11_failover",
                "mode": "failover",
                "n_files": n_files,
                "outage_bridge_s": round(outage_bridge_s, 3),
                "client_errors": client_errors,
                "data_bad": data_bad,
                "failover_retries": reader.failover_retries,
                "failover_redirects": reader.failover_redirects,
                "promoted_records": promoted.promoted_records,
                "promote_waits": promoted.promote_waits,
                "lease_breaks_forced": _sum_srv(cluster,
                                                "lease_breaks_forced"),
                "repl_lag_after": promoted.repl_stats().get("repl_lag", 0),
            }
        finally:
            cluster.shutdown()


def _ttl_waitout(size: int) -> Dict:
    with tempfile.TemporaryDirectory() as root:
        cluster = BuffetCluster(root_dir=root, n_servers=3,
                                lease_ttl_s=WAITOUT_TTL_S)
        try:
            a = BAgent(cluster, read_cache=True)
            alib = BLib(a)
            b = BAgent(cluster)
            blib = BLib(b)
            v1, v2, v3 = (_pattern(k, size) for k in (1, 2, 3))
            blib.write_file("/t", v1)
            assert alib.read_file("/t") == v1  # A now holds a lease

            # leg 1: the revoke cannot reach A — the server must wait
            # the grant out rather than force-break it
            stale_reads = 0
            with partitioned(cluster.transport, a.cb_addr):
                t0 = time.perf_counter()
                blib.write_file("/t", v2)
                waited_s = time.perf_counter() - t0
            if alib.read_file("/t") != v2:
                stale_reads += 1
            ttl_waits = _sum_srv(cluster, "lease_ttl_waits")

            # leg 2: let A's fresh grant expire on its own clock, then
            # write again — the server drops the dead grant RPC-free
            time.sleep(WAITOUT_TTL_S + 0.05)
            blib.write_file("/t", v3)
            if alib.read_file("/t") != v3:
                stale_reads += 1
            cache = a.cache_stats() or {}
            return {
                "bench": "fig11_failover",
                "mode": "ttl_waitout",
                "waited_s": round(waited_s, 3),
                "lease_ttl_waits": ttl_waits,
                "lease_expired_drops": _sum_srv(cluster,
                                                "lease_expired_drops"),
                "lease_breaks_forced": _sum_srv(cluster,
                                                "lease_breaks_forced"),
                "revoke_rpcs_to_client": cache.get("revocations", 0),
                "client_lease_expiries": cache.get("lease_expiries", 0),
                "stale_reads": stale_reads,
            }
        finally:
            cluster.shutdown()


def run(n_files: int = 64, warm_passes: int = 3,
        size: int = 4096) -> List[Dict]:
    return [
        _warm_lease(n_files, warm_passes, size),
        _failover(n_files, size),
        _ttl_waitout(size),
    ]


def check(rows: List[Dict]) -> List[str]:
    """Acceptance gates over `run()` rows; returns failure strings.

    Shared by the `--check` CLI (the CI fault-smoke lane) and
    benchmarks.run so the two gate sets can never drift.  Every gate is
    a counter comparison — never wall-clock.
    """
    failures: List[str] = []
    by_mode = {r.get("mode"): r for r in rows
               if r.get("bench") == "fig11_failover"}
    wl = by_mode.get("warm_lease")
    if wl:
        if wl["warm_crit_per_read"] > 0.01 or wl["lease_expiries"] > 0:
            failures.append(
                f"fig11 warm_lease: {wl['warm_crit_per_read']} crit "
                f"RPCs/read, {wl['lease_expiries']} expiries (warm reads "
                f"under an unexpired TTL must stay RPC-free)")
        if wl["repl_lag_after"] != 0:
            failures.append(
                f"fig11 warm_lease: replication lag {wl['repl_lag_after']} "
                f"after drain (the commit-log shipper stalled)")
    fo = by_mode.get("failover")
    if fo:
        if fo["client_errors"] or fo["data_bad"]:
            failures.append(
                f"fig11 failover: {fo['client_errors']} client errors, "
                f"{fo['data_bad']} corrupt files after promotion (failover "
                f"must be invisible and lossless)")
        if fo["failover_redirects"] < 1:
            failures.append(
                "fig11 failover: client never followed the promotion "
                "redirect (the retry/redirect path regressed)")
        if fo["promote_waits"] < 1:
            failures.append(
                "fig11 failover: promoted standby did not fence its first "
                "mutation behind the lease TTL")
        if fo["repl_lag_after"] != 0:
            failures.append(
                f"fig11 failover: promoted host lag {fo['repl_lag_after']} "
                f"after drain (re-replication to the next standby broke)")
    tw = by_mode.get("ttl_waitout")
    if tw:
        if tw["lease_ttl_waits"] < 1 or tw["lease_expired_drops"] < 1:
            failures.append(
                f"fig11 ttl_waitout: waits={tw['lease_ttl_waits']} "
                f"expired_drops={tw['lease_expired_drops']} (the server "
                f"stopped waiting out / dropping TTL-bounded grants)")
        if tw["stale_reads"]:
            failures.append(
                f"fig11 ttl_waitout: {tw['stale_reads']} stale reads "
                f"(a client served a cached block past its lease)")
    for mode, r in by_mode.items():
        if r["lease_breaks_forced"]:
            failures.append(
                f"fig11 {mode}: {r['lease_breaks_forced']} forced lease "
                f"breaks (TTL discipline must keep this at zero)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-files", type=int, default=64)
    ap.add_argument("--warm-passes", type=int, default=3)
    ap.add_argument("--out", help="write scenario rows to this JSON file")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every acceptance gate holds")
    args = ap.parse_args(argv)
    rows = run(n_files=args.n_files, warm_passes=args.warm_passes)
    print(json.dumps(rows, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
            f.write("\n")
    if args.check:
        failures = check(rows)
        for msg in failures:
            print(f"GATE FAIL: {msg}")
        if failures:
            return 1
        print("fig11 gates: ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
