"""Fault-tolerance demo: a BServer dies mid-run and comes back with a new
incarnation version; clients recover transparently (ESTALE -> version
refresh -> retry), hedged reads dodge the straggler while it is slow, a
home host that dies FOR GOOD is replaced by promoting its replicated
standby (clients bridge the outage with capped-backoff retries and follow
the config redirect; the promoted authority fences its first mutation
behind one lease TTL), and training resumes from the last committed
checkpoint after a simulated coordinator crash.

    PYTHONPATH=src python examples/failover_demo.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import BAgent, BLib, BuffetCluster
from repro.core.failure import server_down, slow_server
from repro.core.inode import Inode
from repro.data import BuffetDataset, DataPipeline, ShardedSampler


def main() -> None:
    root = tempfile.mkdtemp(prefix="buffetfs_failover_")
    cluster = BuffetCluster(root_dir=root, n_servers=4,
                            replication=True, lease_ttl_s=0.3)
    agent = BAgent(cluster)
    lib = BLib(agent)

    # corpus with replicas (hedged-read targets)
    rng = np.random.default_rng(0)
    samples = [rng.integers(1, 1000, size=64).astype(np.uint16)
               for _ in range(64)]
    ds = BuffetDataset.build(lib, samples, name="fo", replicate=True)

    # --- 1. server restart: version bump, client recovers -----------------
    host = Inode.unpack(agent.stat_cached(ds.sample_path(0))["ino"]).host_id
    v0 = cluster.servers[host].version
    cluster.restart_server(host)
    print(f"[1] server {host} restarted: incarnation {v0} -> "
          f"{cluster.servers[host].version}")
    x = ds.read_sample(0)
    assert np.array_equal(x, samples[0])
    print("    client read through transparently (ESTALE -> refresh -> retry)")

    # --- 2. hedged reads mask a straggler ---------------------------------
    pipe = DataPipeline(ds, ShardedSampler(n_samples=64, global_batch=8,
                                           dp_rank=0, dp_size=1),
                        seq_len=32, hedge_delay_s=0.05)
    shard_host = Inode.unpack(
        agent.stat_cached(f"{ds.base}/shard_0000")["ino"]).host_id
    with slow_server(cluster, shard_host, extra_delay_s=0.5):
        it = iter(pipe)
        t0 = time.time()
        batch = next(it)
        dt = time.time() - t0
    print(f"[2] straggling server masked: batch in {dt:.2f}s "
          f"(hedged={pipe.stats.hedged}, wins={pipe.stats.hedge_wins})")
    pipe.stop()

    # --- 3. downtime: reads fail over to the replica path -----------------
    pipe2 = DataPipeline(ds, ShardedSampler(n_samples=64, global_batch=8,
                                            dp_rank=0, dp_size=1),
                         seq_len=32, hedge_delay_s=0.05)
    with server_down(cluster, shard_host):
        it = iter(pipe2)
        batch = next(it)
        print(f"[3] server {shard_host} DOWN: batch still served "
              f"(hedge_wins={pipe2.stats.hedge_wins})")
    pipe2.stop()

    # --- 4. permanent home-host death: promote the standby ----------------
    lib.makedirs("/prom")
    lib.write_file("/prom/precious", b"survives the home host")
    victim = Inode.unpack(agent.stat_cached("/prom/precious")["ino"]).host_id
    for srv in cluster.servers.values():
        srv.repl_drain()  # commit logs converged on the standbys
    cluster.kill_server(victim)
    new_ver = cluster.promote(victim)  # the admin runbook's config push
    assert lib.read_file("/prom/precious") == b"survives the home host"
    lib.write_file("/prom/precious", b"and writes work too")  # TTL-fenced
    promoted = cluster.servers[victim]
    print(f"[4] home {victim} dead for good: standby promoted "
          f"(incarnation -> {new_ver}, {promoted.promoted_records} records "
          f"replayed, first write fenced {promoted.promote_waits}x, "
          f"forced lease breaks: {promoted.lease_breaks_forced})")

    # --- 5. crash/restart training resume ---------------------------------
    from repro.launch.train import Trainer, TrainerConfig
    tc = TrainerConfig(arch="stablelm-3b", steps=6, global_batch=4, seq_len=32,
                       ckpt_every=3, log_every=100, data_dir=root,
                       n_servers=4, run_name="fo")
    tr = Trainer(tc, cluster=cluster)
    tr.run()
    tr.pipeline.stop()
    tc2 = TrainerConfig(arch="stablelm-3b", steps=8, global_batch=4, seq_len=32,
                        ckpt_every=3, log_every=100, data_dir=root,
                        n_servers=4, run_name="fo")
    tr2 = Trainer(tc2, cluster=cluster)
    tr2.init_or_restore()
    print(f"[5] after 'crash': resumed at step {tr2.start_step} "
          f"(sampler cursor {tr2.sampler.step})")
    tr2.run()
    tr2.pipeline.stop()

    agent.shutdown()
    cluster.shutdown()
    print("done.")


if __name__ == "__main__":
    main()
