"""Batched serving example: prefill a batch of prompts, stream greedy decode
through the same serve_step the multi-pod dry-run lowers.

    PYTHONPATH=src python examples/serve_batch.py --arch chatglm3-6b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch.serve import Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    srv = Server(args.arch, reduced=True, max_len=64)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, srv.cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    out = srv.generate(prompts, args.tokens)
    print(f"[serve] {args.arch} (reduced): prefill {out['prefill_s']*1e3:.0f}ms, "
          f"{out['decode_tok_per_s']:.1f} tok/s decode")
    print("[serve] first 8 generated ids per sequence:")
    print(out["tokens"][:, :8])


if __name__ == "__main__":
    main()
