"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps,
fed entirely through BuffetFS (small-file corpus, prefetch + hedged reads)
with async atomic checkpointing and crash-safe resume.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
    PYTHONPATH=src python examples/train_e2e.py --steps 300 --resume  # after kill
"""
import argparse
import os
import sys
from dataclasses import replace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.launch.train import Trainer, TrainerConfig


def model_100m():
    """~98M params: stablelm family scaled (d=640, L=10, ff=2560, tied 50k vocab)."""
    base = get_config("stablelm-3b")
    return replace(base, n_layers=10, d_model=640, n_heads=10, n_kv_heads=10,
                   d_head=64, d_ff=2560, vocab_size=50304,
                   tie_embeddings=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data-dir", default=None)
    args = ap.parse_args()

    cfg = model_100m()
    from repro.analysis.model_math import param_counts
    n = param_counts(cfg)["total"]
    print(f"[e2e] model: {n/1e6:.1f}M params")

    tc = TrainerConfig(arch="stablelm-3b", reduced=False, steps=args.steps,
                       global_batch=args.batch, seq_len=args.seq, lr=6e-4,
                       ckpt_every=50, log_every=10, run_name="e2e100m",
                       data_dir=args.data_dir, hedge_delay_s=0.5)

    # synthetic but LEARNABLE corpus: Zipfian bigram chains
    rng = np.random.default_rng(0)
    trans = rng.zipf(1.5, size=(256,)).astype(np.int64) % cfg.vocab_size
    corpus = []
    for _ in range(512):
        s = np.empty(args.seq + 1, np.uint32)
        s[0] = rng.integers(0, 256)
        for t in range(1, args.seq + 1):
            s[t] = (trans[s[t - 1] % 256] + rng.integers(0, 3)) % cfg.vocab_size
        corpus.append(s)

    tr = Trainer(tc, corpus=corpus)
    tr.cfg = cfg  # use the ~100M config built above
    import jax
    from repro.runtime.steps import make_train_step_fn
    from repro.optim import AdamWConfig
    tr.opt_cfg = AdamWConfig(lr=6e-4, total_steps=args.steps,
                             warmup_steps=max(1, args.steps // 20))
    tr.step_fn = jax.jit(make_train_step_fn(tr.cfg, tr.opt_cfg),
                         donate_argnums=(0,))
    out = tr.run()
    print(f"[e2e] finished: {out}")
    tr.shutdown()


if __name__ == "__main__":
    main()
