"""Quickstart: BuffetFS in 60 seconds.

Spins up a 4-server decentralized BuffetFS cluster, shows the paper's
mechanism (zero-RPC open() once directories are cached, deferred open
recording, async close), compares RPC counts against the Lustre baselines,
and runs a few training steps fed by a BuffetFS-served corpus.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (BAgent, BLib, BuffetCluster, LustreNormalClient,
                        O_RDONLY)


def main() -> None:
    root = tempfile.mkdtemp(prefix="buffetfs_quickstart_")
    cluster = BuffetCluster(root_dir=root, n_servers=4)
    agent = BAgent(cluster)
    lib = BLib(agent)

    # --- 1. the namespace is decentralized: dirs hash to servers ----------
    lib.makedirs("/data/shard_a")
    lib.makedirs("/data/shard_b")
    for i in range(16):
        lib.write_file(f"/data/shard_a/sample_{i}.bin", os.urandom(256))
    print("[1] wrote 16 small files across", cluster.n_servers, "servers")

    # --- 2. the paper's headline: open() with ZERO rpcs -------------------
    agent.warm("/data/shard_a")
    agent.drain()
    agent.stats.reset()
    fd = agent.open("/data/shard_a/sample_7.bin", O_RDONLY)
    print("[2] open() issued", agent.stats.total, "RPCs "
          "(permission checked client-side from the cached 10-byte records)")
    data = agent.read(fd)
    agent.close(fd)  # returns immediately; CLOSE rpc is async
    agent.drain()
    snap = agent.stats.snapshot()
    print(f"    full open/read/close: {snap['critical_path']} critical RPC, "
          f"{snap['async_offpath']} async ({snap['by_type']})")

    # --- 3. versus Lustre-Normal (its namespace lives on the MDS) ---------
    from repro.core.perms import O_CREAT, O_WRONLY
    lc = LustreNormalClient(cluster)
    lc.mkdir("/lustre")
    wfd = lc.open("/lustre/sample.bin", O_WRONLY | O_CREAT)
    lc.write(wfd, os.urandom(256))
    lc.close(wfd)
    lc.drain()
    lc.stats.reset()
    lfd = lc.open("/lustre/sample.bin", O_RDONLY)
    lc.read(lfd)
    lc.close(lfd)
    lc.drain()
    lsnap = lc.stats.snapshot()
    print(f"[3] lustre-normal same access: {lsnap['critical_path']} critical "
          f"RPCs ({lsnap['by_type']})")
    lc.shutdown()

    # --- 4. a few training steps over a BuffetFS-served pipeline ----------
    from repro.launch.train import Trainer, TrainerConfig
    tc = TrainerConfig(arch="stablelm-3b", steps=6, global_batch=4,
                       seq_len=32, ckpt_every=3, log_every=3,
                       data_dir=root, n_servers=4)
    t0 = time.time()
    tr = Trainer(tc, cluster=cluster)
    out = tr.run()
    print(f"[4] trained 6 steps in {time.time()-t0:.1f}s, "
          f"loss={out['final_loss']:.3f}, "
          f"{out['critical_rpcs']} critical / {out['async_rpcs']} async RPCs")
    tr.pipeline.stop()
    agent.shutdown()
    cluster.shutdown()
    print("done.")


if __name__ == "__main__":
    main()
